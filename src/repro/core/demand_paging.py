"""Demand paging: host-DRAM ↔ HBM base-page transfers.

Paper §1: demand paging transfers a page over the system I/O bus when a
thread touches an unallocated page; Mosaic's point is that transfers stay at
*base-page* granularity even when translation uses large pages, so a fault
never over-fetches.

TPU adaptation (DESIGN.md §2): the "system I/O bus" is the host↔device link
(PCIe on TPU hosts too).  The serving engine keeps cold KV pages in host
DRAM (prefix caches, preempted requests, >HBM working sets) and faults them
in at base-page granularity.  This module tracks residency and batches the
faults of one engine step into a single gather-transfer (one device_put per
step rather than per page), which is how a real TPU host would amortize
launch overhead.

Contiguity helps *transfer* too (paper §4.2): base pages that are
physically contiguous — which under Mosaic they are whenever CoCoA kept the
frame intact — merge into a single DMA descriptor, so a batch of faults
pays one setup cost per contiguous run rather than one per page.
:class:`FaultBatch` makes that executable: it splits the faulted ppns into
maximal contiguous runs and charges ``setup_us`` once per run.

Latency accounting mirrors the paper's PCIe model (measured GTX 1080 curves:
fixed setup cost + per-byte cost) so the TLB/paging simulator and the real
engine agree on what a fault costs; see :mod:`repro.core.tlb_sim`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np

# Paper's base page (4KB); engines override with the true KV bytes/page.
DEFAULT_PAGE_BYTES = 4096


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """System I/O bus latency model (paper §3: modeled from GTX 1080).

    latency(bytes) = setup_us + bytes / bandwidth_gbps
    """

    setup_us: float = 10.0          # per-transfer fixed cost (driver+DMA setup)
    bandwidth_GBps: float = 12.0    # effective PCIe 3.0 x16 ≈ 12 GB/s

    def transfer_us(self, nbytes: int) -> float:
        return self.setup_us + nbytes / (self.bandwidth_GBps * 1e3)


def contiguous_runs(ppns: Sequence[int]) -> List[Tuple[int, int]]:
    """Maximal runs of physically-contiguous pages as (start, length).

    The input order is irrelevant: DMA descriptors address physical memory,
    so runs are computed over the sorted ppn set.
    """
    if not ppns:
        return []
    ps = sorted(set(int(p) for p in ppns))
    runs: List[Tuple[int, int]] = []
    start = prev = ps[0]
    for p in ps[1:]:
        if p == prev + 1:
            prev = p
            continue
        runs.append((start, prev - start + 1))
        start = prev = p
    runs.append((start, prev - start + 1))
    return runs


@dataclasses.dataclass
class FaultBatch:
    """One engine-step's worth of page faults, batched for transfer.

    Base pages belonging to the same coalesced frame are physically
    contiguous (CoCoA), so they merge into one DMA; scattered pages pay one
    setup each.  This is where contiguity helps *transfer* too.
    """

    ppns: List[int]
    page_bytes: int
    link: LinkModel

    @property
    def nbytes(self) -> int:
        return len(self.ppns) * self.page_bytes

    @functools.cached_property
    def runs(self) -> List[Tuple[int, int]]:
        # Effectively immutable after construction; callers read dma_count
        # and transfer_us repeatedly on the fault hot path.
        return contiguous_runs(self.ppns)

    @property
    def dma_count(self) -> int:
        """Number of DMA descriptors (one per contiguous run)."""
        return len(self.runs)

    @property
    def transfer_us(self) -> float:
        if not self.ppns:
            return 0.0
        return sum(self.link.transfer_us(n * self.page_bytes)
                   for _, n in self.runs)


class ResidencyTracker:
    """Tracks which physical pages are HBM-resident vs host-only.

    Lifecycle hooks (called by the managers, DESIGN.md §6):

    * ``mark_resident`` — a freshly-allocated page is device-written by the
      next prefill/decode step, so it is resident with zero transfer;
    * ``demote`` — the page's payload lives in the host tier (a resumed
      request's re-allocated pages); the next ``touch`` reports it missing;
    * ``fault_in`` — batch host→device transfer, accounted per DMA run;
    * ``evict`` — device→host transfer (preemption / cold-page spill);
    * ``release`` — the allocator freed the page: residency drops silently;
    * ``on_copy`` — a compaction ``CopyOp`` moved the payload on-device:
      the destination inherits the source's residency state.
    """

    def __init__(self, num_pages: int, page_bytes: int, link: LinkModel | None = None):
        self.resident = np.zeros(num_pages, dtype=bool)
        self.page_bytes = page_bytes
        self.link = link or LinkModel()
        self.stats = {"faults": 0, "fault_batches": 0, "dma_transfers": 0,
                      "bytes_in": 0, "evictions": 0, "bytes_out": 0,
                      "transfer_us": 0.0}

    def touch(self, ppns: Sequence[int]) -> List[int]:
        """Mark pages as about-to-be-accessed; return the non-resident ones."""
        missing = [p for p in ppns if not self.resident[p]]
        return missing

    def fault_in(self, ppns: Sequence[int]) -> FaultBatch:
        """Batch-fault pages in; marks them resident and accounts transfer."""
        missing = [p for p in ppns if not self.resident[p]]
        for p in missing:
            self.resident[p] = True
        batch = FaultBatch(missing, self.page_bytes, self.link)
        if missing:
            self.stats["faults"] += len(missing)
            self.stats["fault_batches"] += 1
            self.stats["dma_transfers"] += batch.dma_count
            self.stats["bytes_in"] += batch.nbytes
            self.stats["transfer_us"] += batch.transfer_us
        return batch

    def evict(self, ppns: Sequence[int]) -> int:
        """Device→host spill: accounts the outbound transfer."""
        n = 0
        for p in ppns:
            if self.resident[p]:
                self.resident[p] = False
                n += 1
        self.stats["evictions"] += n
        self.stats["bytes_out"] += n * self.page_bytes
        return n

    def mark_resident(self, ppns: Sequence[int]) -> None:
        """Freshly-allocated pages: device-written, no transfer."""
        for p in ppns:
            self.resident[p] = True

    def demote(self, ppns: Sequence[int]) -> None:
        """Payload lives on host (already accounted at eviction time)."""
        for p in ppns:
            self.resident[p] = False

    def release(self, ppns: Sequence[int]) -> None:
        """Pages freed by the allocator: drop residency without transfer."""
        for p in ppns:
            self.resident[p] = False

    def on_copy(self, src_ppn: int, dst_ppn: int) -> None:
        """Compaction moved the payload src→dst on-device: residency moves
        with it (a non-resident source stays host-backed at the new ppn)."""
        self.resident[dst_ppn] = self.resident[src_ppn]
        self.resident[src_ppn] = False
