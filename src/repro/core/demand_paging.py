"""Demand paging: host-DRAM ↔ HBM base-page transfers.

Paper §1: demand paging transfers a page over the system I/O bus when a
thread touches an unallocated page; Mosaic's point is that transfers stay at
*base-page* granularity even when translation uses large pages, so a fault
never over-fetches.

TPU adaptation (DESIGN.md §2): the "system I/O bus" is the host↔device link
(PCIe on TPU hosts too).  The serving engine keeps cold KV pages in host
DRAM (prefix caches, preempted requests, >HBM working sets) and faults them
in at base-page granularity.  This module tracks residency and batches the
faults of one engine step into a single gather-transfer (one device_put per
step rather than per page), which is how a real TPU host would amortize
launch overhead.

Latency accounting mirrors the paper's PCIe model (measured GTX 1080 curves:
fixed setup cost + per-byte cost) so the TLB/paging simulator and the real
engine agree on what a fault costs; see :mod:`repro.core.tlb_sim`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """System I/O bus latency model (paper §3: modeled from GTX 1080).

    latency(bytes) = setup_us + bytes / bandwidth_gbps
    """

    setup_us: float = 10.0          # per-transfer fixed cost (driver+DMA setup)
    bandwidth_GBps: float = 12.0    # effective PCIe 3.0 x16 ≈ 12 GB/s

    def transfer_us(self, nbytes: int) -> float:
        return self.setup_us + nbytes / (self.bandwidth_GBps * 1e3)


@dataclasses.dataclass
class FaultBatch:
    """One engine-step's worth of page faults, batched for transfer."""

    ppns: List[int]
    page_bytes: int
    link: LinkModel

    @property
    def nbytes(self) -> int:
        return len(self.ppns) * self.page_bytes

    @property
    def transfer_us(self) -> float:
        if not self.ppns:
            return 0.0
        # Base pages belonging to the same coalesced frame are physically
        # contiguous (CoCoA), so they merge into one DMA; scattered pages pay
        # one setup each.  This is where contiguity helps *transfer* too.
        return self.link.transfer_us(self.nbytes)


class ResidencyTracker:
    """Tracks which physical pages are HBM-resident vs host-only."""

    def __init__(self, num_pages: int, page_bytes: int, link: LinkModel | None = None):
        self.resident = np.zeros(num_pages, dtype=bool)
        self.page_bytes = page_bytes
        self.link = link or LinkModel()
        self.stats = {"faults": 0, "fault_batches": 0, "bytes_in": 0,
                      "evictions": 0, "bytes_out": 0, "transfer_us": 0.0}

    def touch(self, ppns: Sequence[int]) -> List[int]:
        """Mark pages as about-to-be-accessed; return the non-resident ones."""
        missing = [p for p in ppns if not self.resident[p]]
        return missing

    def fault_in(self, ppns: Sequence[int]) -> FaultBatch:
        """Batch-fault pages in; marks them resident and accounts transfer."""
        missing = [p for p in ppns if not self.resident[p]]
        for p in missing:
            self.resident[p] = True
        batch = FaultBatch(missing, self.page_bytes, self.link)
        if missing:
            self.stats["faults"] += len(missing)
            self.stats["fault_batches"] += 1
            self.stats["bytes_in"] += batch.nbytes
            self.stats["transfer_us"] += batch.transfer_us
        return batch

    def evict(self, ppns: Sequence[int]) -> int:
        n = 0
        for p in ppns:
            if self.resident[p]:
                self.resident[p] = False
                n += 1
        self.stats["evictions"] += n
        self.stats["bytes_out"] += n * self.page_bytes
        return n

    def release(self, ppns: Sequence[int]) -> None:
        """Pages freed by the allocator: drop residency without transfer."""
        for p in ppns:
            self.resident[p] = False
