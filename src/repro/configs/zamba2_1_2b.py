"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf]  38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000, ssm_state=64.  A single shared transformer block (attention +
MLP, weights reused) is invoked every 6 mamba layers; its KV cache is paged
through Mosaic (DESIGN.md §4).  The published model applies per-invocation
LoRA deltas to the shared block; we share weights exactly (disclosed).
"""

from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    hybrid=HybridConfig(period=6, n_shared_blocks=1),
    source="arXiv:2411.15242; hf",
)


def smoke_config():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, head_dim=16, max_seq_len=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
        hybrid=HybridConfig(period=2, n_shared_blocks=1),
    )
