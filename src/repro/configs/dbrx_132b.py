"""dbrx-132b — fine-grained MoE, GQA.

[hf:databricks/dbrx-base; unverified]  40L d_model=6144 48H (GQA kv=8)
d_ff=10752(expert) vocab=100352, 16 experts top-4.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752),
    source="hf:databricks/dbrx-base; unverified",
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=96,
        vocab_size=512, max_seq_len=512,
        moe=dataclasses.replace(CONFIG.moe, n_experts=4, top_k=2, d_expert=96,
                                capacity_factor=4.0),
    )
