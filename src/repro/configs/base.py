"""Config dataclasses for models, shapes, pools, and runs.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact published configuration) and ``smoke_config()`` (a
reduced same-family variant for CPU tests).  ``repro.configs.get_config``
is the registry entry point used by ``--arch <id>`` everywhere.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    n_shared: int = 0              # always-on shared experts
    capacity_factor: float = 1.25  # dispatch capacity (GShard-style)
    router_dtype: str = "float32"
    first_dense: int = 0           # leading layers that use a dense FFN


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention geometry."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0: no q compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD geometry."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: shared attention block every ``period`` layers."""

    period: int = 6                # insert shared block after every N ssm layers
    n_shared_blocks: int = 1       # distinct shared blocks cycled through


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 24
    dec_layers: int = 24
    cross_attention: bool = True
    source_len: int = 4096         # encoder memory length for decode shapes


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 524288
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[str] = None  # 'audio' | 'vision' modality stub
    frontend_tokens: int = 0        # prefix embeddings supplied by the stub
    dtype: str = "bfloat16"
    # Citation bookkeeping ([source; verified-tier] from the assignment).
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
                + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                + d_in * d + d_in
            )
            return emb + L * per
        dh = self.resolved_head_dim
        if self.mla is not None:
            m = self.mla
            qdim = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            per_attn = (
                d * (m.kv_lora_rank + m.qk_rope_head_dim)       # kv down + rope k
                + (d * qdim if m.q_lora_rank == 0
                   else d * m.q_lora_rank + m.q_lora_rank * qdim)
                + m.kv_lora_rank * self.n_heads
                * (m.qk_nope_head_dim + m.v_head_dim)            # kv up
                + self.n_heads * m.v_head_dim * d                # out proj
            )
        else:
            kv = self.n_kv_heads * dh
            per_attn = d * (self.n_heads * dh + 2 * kv) + self.n_heads * dh * d
        if self.moe is not None:
            mo = self.moe
            dense_ffn = 3 * d * self.d_ff
            expert_ffn = 3 * d * mo.d_expert
            moe_layers = L - mo.first_dense
            per_ffn_moe = (
                (mo.n_experts + mo.n_shared) * expert_ffn + d * mo.n_experts
            )
            ffn_total = mo.first_dense * dense_ffn + moe_layers * per_ffn_moe
        else:
            ffn_total = L * 3 * d * self.d_ff
        total = emb + L * per_attn + ffn_total
        if self.encdec is not None:
            total += L * per_attn  # cross-attention in decoder layers
        if self.hybrid is not None:
            # Mamba2 backbone + shared attention block(s).
            s = self.ssm
            d_in = s.expand * d
            per_ssm = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
                + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                + d_in * d + d_in
            )
            shared = self.hybrid.n_shared_blocks * (per_attn + 3 * d * self.d_ff)
            return emb + L * per_ssm + shared
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters — 6·N_active·D for MoE rooflines."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d, L = self.d_model, self.n_layers
        inactive = (mo.n_experts - mo.top_k) * 3 * d * mo.d_expert
        return self.param_count() - (L - mo.first_dense) * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


# The four assigned LM shapes (identical across the 10 architectures).
SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524288, 1,   "decode"),
}


@dataclasses.dataclass(frozen=True)
class PoolGeometry:
    """Mosaic KV-pool geometry for serving (DESIGN.md §5)."""

    page_tokens: int = 64
    frame_pages: int = 16
    headroom: float = 1.25
    compact_threshold: float = 0.5

    def pages_for(self, seq_len: int, batch: int) -> int:
        per_seq = (seq_len + self.page_tokens - 1) // self.page_tokens
        raw = int(np.ceil(per_seq * batch * self.headroom))
        return ((raw + self.frame_pages - 1) // self.frame_pages) * self.frame_pages


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatch: int = 0            # 0: no gradient accumulation
    remat: str = "block"           # 'none' | 'block'
    zero1: bool = True             # shard optimizer state over data axis
    grad_compress: bool = False    # int8 all-reduce with error feedback
    parallelism: str = "megatron"  # 'megatron' (TP/EP over model axis) |
                                   # 'fsdp' (every axis data-parallel,
                                   #  ZeRO-3 weight streaming)
