"""seamless-m4t-large-v2 — encoder-decoder, multimodal (audio frontend stub).

[arXiv:2308.11596; hf]  24L(+24L dec) d_model=1024 16H d_ff=8192
vocab=256206.  The speech frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (DESIGN.md §4).
"""

from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10000.0,
    encdec=EncDecConfig(enc_layers=24, dec_layers=24, source_len=4096),
    frontend="audio",
    source="arXiv:2308.11596; hf",
)


def smoke_config():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, max_seq_len=512,
        encdec=EncDecConfig(enc_layers=2, dec_layers=2, source_len=64),
    )
