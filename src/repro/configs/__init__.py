"""Architecture registry: ``--arch <id>`` → ModelConfig.

``ARCHS`` maps the assignment's architecture ids to config modules; each
module defines the exact published ``CONFIG`` plus a reduced
``smoke_config()`` of the same family for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    EncDecConfig,
    HybridConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    PoolGeometry,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    TrainHParams,
)

ARCHS: Dict[str, str] = {
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "llama3-8b": "repro.configs.llama3_8b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
}


def list_archs() -> List[str]:
    return list(ARCHS)


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(ARCHS[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(ARCHS[arch]).smoke_config()


__all__ = [
    "ARCHS", "list_archs", "get_config", "get_smoke_config",
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig",
    "EncDecConfig", "ShapeConfig", "SHAPES", "PoolGeometry", "TrainHParams",
]
