"""deepseek-v2-lite-16b — MoE with Multi-head Latent Attention.

[arXiv:2405.04434; hf]  27L d_model=2048 16H d_ff=1408(expert) vocab=102400,
MLA kv_lora=512, 64 routed experts top-6 + 2 shared, first layer dense FFN
(d_ff dense = 10944 per the HF config).

Assignment-line note (DESIGN.md §4): the line reads "2 shared+160 routed";
160 routed is DeepSeek-V2-full.  We follow the primary spec "MoE 64e top-6"
= V2-Lite: 64 routed + 2 shared.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                      # dense-FFN layers (layer 0)
    vocab_size=102400,
    head_dim=192,                    # qk_nope 128 + qk_rope 64
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  first_dense=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434; hf",
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=512, head_dim=24, max_seq_len=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32, n_shared=1,
                      first_dense=1, capacity_factor=4.0),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
    )
