"""phi3-mini-3.8b — dense, MHA (kv=32), RoPE SwiGLU.

[arXiv:2404.14219; unverified]  32L d_model=3072 32H (GQA kv=32)
d_ff=8192 vocab=32064.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    source="arXiv:2404.14219; unverified",
)


def smoke_config():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, max_seq_len=512,
    )
