"""mamba2-1.3b — attention-free SSM (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=2048 d_ff=0 vocab=50280,
ssm_state=128.  No KV cache; decode state is O(1) per layer — Mosaic's KV
path is N/A for this arch (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)


def smoke_config():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=512, max_seq_len=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
    )
