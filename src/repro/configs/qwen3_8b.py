"""qwen3-8b — dense, GQA kv=8, qk-norm.

[hf:Qwen/Qwen3-8B; hf]  36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-8B; hf",
)


def smoke_config():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=16, max_seq_len=512,
    )
