"""llava-next-mistral-7b — VLM: Mistral-7B backbone, anyres patch stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The vision tower is a STUB:
``input_specs()`` provides precomputed patch embeddings (anyres tiling →
up to 2880 image tokens prepended to the prompt).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1000000.0,
    frontend="vision",
    frontend_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)


def smoke_config():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, frontend_tokens=16, max_seq_len=512,
    )
