"""Quickstart: the Mosaic memory manager in 60 seconds.

Shows the paper's three components working on a live pool:
  1. CoCoA en-masse allocation  -> contiguity conserved
  2. In-Place Coalescer         -> metadata-only large pages (zero copies)
  3. CAC                        -> fragmentation -> splinter + compact

and the contrast with the GPU-MMU baseline (paper Fig. 2): same workload,
interleaved frames, zero coalescing opportunities.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.baseline_mmu import BaselineMMU
from repro.core.manager import MosaicManager
from repro.core.pagepool import PoolConfig

CFG = PoolConfig(num_pages=64, frame_pages=8, page_tokens=64,
                 compact_threshold=0.5)


def show(mgr, title):
    s = mgr.stats()
    print(f"  [{title}] occupancy={s['occupancy']:.0%} "
          f"coalesced={s['coalesced_fraction']:.0%} "
          f"bloat={s['memory_bloat']:.2f} "
          f"copies={s.get('compaction_copies', 0)}")


def main():
    print("== Mosaic: en-masse allocation from two tenants")
    mosaic = MosaicManager(CFG)
    baseline = BaselineMMU(CFG)
    # Two applications allocate interleaved buffers (paper Fig. 2 setting).
    for rep in range(2):
        for owner in (0, 1):
            mosaic.allocate_tokens(owner, 9 * CFG.page_tokens)
            baseline.allocate_tokens(owner, 9 * CFG.page_tokens)
    show(mosaic, "mosaic   ")
    show(baseline, "gpu-mmu  ")
    print(f"  baseline frames holding >1 app: "
          f"{baseline.multi_owner_frames()} "
          f"(coalesce opportunities: {baseline.coalesce_opportunities})")
    print(f"  mosaic coalesce ops: {mosaic.pool.stats['coalesce_ops']} "
          f"with {mosaic.pool.stats['compaction_copies']} copies "
          f"(in-place promotion)")

    print("\n== Deallocation: tenant 0 exits; tenant 1 trims -> CAC")
    mosaic.deallocate(0)
    t1 = mosaic.table(1)
    mosaic.free_pages(1, t1.mapped_vpns()[1::3])   # fragment tenant 1
    plan = mosaic.drain_copy_ops()
    show(mosaic, "after CAC")
    print(f"  CAC plan: {len(plan)} page copies "
          f"(device batch for the page_compact kernel)")
    mosaic.check_invariants()
    print("  invariants: OK")

    print("\n== Decode-time growth: appended pages coalesce at frame fill")
    mgr = MosaicManager(CFG)
    for step in range(CFG.frame_pages * CFG.page_tokens):
        mgr.append_tokens(7, 1)
    print(f"  after {CFG.frame_pages * CFG.page_tokens} tokens: "
          f"vframe0 coalesced = {mgr.table(7).coalesced[0]} "
          f"(copies: {mgr.pool.stats['compaction_copies']})")


if __name__ == "__main__":
    main()
