"""End-to-end training driver: ~100M-param dense LM for a few hundred steps.

Demonstrates the full training substrate on whatever devices exist:
config -> mesh -> pjit train step (remat, ZeRO-1) -> synthetic data
pipeline -> fault-tolerant loop (atomic checkpoints, SIGTERM-safe) ->
restart-and-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume

(~100M params is deliberate: big enough to be a real model, small enough
for CPU. On a TPU slice the same script runs with the production mesh.)
"""

import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig, TrainHParams
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer


def model_100m() -> ModelConfig:
    # llama-family dense decoder, ~100M params.
    return ModelConfig(
        name="demo-100m", family="dense",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=1536, vocab_size=32000, rope_theta=10000.0,
        tie_embeddings=True, source="examples/train_lm.py",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-sized model (CI)")
    args = ap.parse_args()

    cfg = get_smoke_config("llama3-8b") if args.tiny else model_100m()
    n_params_est = cfg.param_count()
    print(f"model: {cfg.name} ({n_params_est / 1e6:.1f}M params)")

    hp = TrainHParams(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                      microbatch=2, remat="block")
    mesh = make_host_mesh()
    print(f"mesh: {dict(mesh.shape)}")
    tr = Trainer(cfg, hp, mesh, batch_per_step=args.batch,
                 seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                 ckpt_every=50, resume=args.resume)
    if args.resume:
        print(f"resuming from step {tr.start_step}")
    hist = tr.run(args.steps, log_every=10)
    if hist:
        print(f"loss: {hist[0][1]:.3f} -> {hist[-1][1]:.3f} "
              f"over {len(hist)} logged points")


if __name__ == "__main__":
    main()
