"""Multi-tenant serving on the Mosaic pool — the paper's setting as an
LLM-serving system.

Three tenants submit batched requests to one engine sharing one physical
KV pool.  The run reports, per manager (mosaic vs gpu-mmu baseline):
tokens/s, coalesced fraction (TLB-reach analogue), CAC compaction traffic,
and verifies the outputs are bit-identical — the manager is
application-transparent, the paper's headline property.

With ``--oversubscribe F`` the pool holds only 1/F of the sized-for-peak
KV working set (DESIGN.md §6): low-priority requests get preempted to the
host tier under pool pressure and resumed later via base-page demand
fault-in; the report adds swap counts, faults, merged-DMA counts and
modeled I/O-bus microseconds — and the outputs still match the
pressure-free run token-for-token.

With ``--shared-prefix N`` every prompt starts with the same N-token
system prompt (the multi-tenant reuse setting, DESIGN.md §8): finished
requests park the prefix's KV pages in the content-hash prefix cache,
and later admissions fault them back in through the DMA pipeline instead
of re-decoding them — watch ``prefix hit/miss`` and ``tok reused`` in
the report, and the eviction/parking gathers riding the duplex "out"
lanes.  ``--no-prefix-cache`` disables reuse for comparison (tokens are
byte-identical either way).

With ``--engines N`` the same workload runs on a cluster of N engine
replicas over one shared host tier (DESIGN.md §10): the deadline-aware
router load-balances admissions, the shared content-hash index lets a
prefix parked by one replica hit on every other, and work stealing
migrates preempted requests between replicas through host-frame leases
(zero re-prefill).  Outputs stay byte-identical to the 1-engine run.

With ``--capacity-frames N`` (cluster mode) host DRAM itself is bounded
to N frames and the disk spill tier opens underneath (DESIGN.md §11):
LRU frames ride the outbound DMA lanes into frame-granular disk files
and promote back on touch; ``--no-spill`` switches to the hard-capped
baseline that drops over-cap prefix frames through the index instead.
Tokens are byte-identical in every configuration — watch the ``spill``
line of the cluster summary.

    PYTHONPATH=src python examples/serve_multitenant.py --requests 10
    PYTHONPATH=src python examples/serve_multitenant.py --requests 12 \
        --oversubscribe 2
    PYTHONPATH=src python examples/serve_multitenant.py --requests 12 \
        --shared-prefix 40
    PYTHONPATH=src python examples/serve_multitenant.py --requests 12 \
        --shared-prefix 40 --engines 2
    PYTHONPATH=src python examples/serve_multitenant.py --requests 12 \
        --shared-prefix 40 --engines 2 --capacity-frames 4
"""

import argparse

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, ServingEngine


def run(manager_kind: str, n_requests: int, seed: int,
        oversubscribe: float = 1.0, fault_mode: str = "async",
        shared_prefix: int = 0, prefix_cache: bool = True,
        n_engines: int = 1, capacity_frames=None, spill: bool = True,
        translation: str = "off"):
    cfg = get_smoke_config("qwen2.5-3b")
    geo = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)
    if n_engines > 1:
        cluster = ServingCluster(
            cfg, geometry=geo, n_engines=n_engines, max_batch=4,
            max_seq=128, manager_kind=manager_kind, seed=seed,
            oversubscription=oversubscribe, fault_mode=fault_mode,
            prefix_cache=prefix_cache,
            capacity_frames=capacity_frames, spill=spill,
            translation=translation)
        eng = cluster            # same submit/run_until_drained surface
    else:
        cluster = None
        eng = ServingEngine(cfg, geometry=geo, max_batch=4, max_seq=128,
                            manager_kind=manager_kind, seed=seed,
                            oversubscription=oversubscribe,
                            fault_mode=fault_mode,
                            prefix_cache=prefix_cache,
                            translation=translation)
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab_size,
                          shared_prefix).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        T = int(rng.integers(16, 72)) if oversubscribe == 1.0 \
            else int(rng.integers(56, 104))
        prompt = rng.integers(0, cfg.vocab_size, T).astype(np.int32)
        if shared_prefix:
            prompt = np.concatenate([system, prompt])
        reqs.append(Request(
            rid=i, tenant=i % 3,
            # Tenant 0 is the premium tier: its requests are never the
            # preemption victim while lower tiers are runnable.
            priority=1 if i % 3 == 0 else 0,
            prompt=prompt,
            max_new=int(rng.integers(4, 12))))
    # With a shared prefix, submit in two waves so the first completions
    # park the prefix before the rest admit (reuse needs a warm index).
    wave1 = reqs[:2] if shared_prefix else reqs
    for r in wave1:
        eng.submit(r)
    steps = eng.run_until_drained(max_steps=5000)
    for r in reqs[len(wave1):]:
        eng.submit(r)
    steps += eng.run_until_drained(max_steps=5000)
    assert all(r.done for r in reqs)
    return eng, reqs, steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--oversubscribe", type=float, default=1.0,
                    help="pool = sized-for-peak working set / this factor")
    ap.add_argument("--fault-mode", choices=("async", "sync"),
                    default="async",
                    help="async = double-buffered prefetch pipeline "
                         "(DESIGN.md §7); sync = PR 1's blocking fault-in")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of shared system prompt prepended to "
                         "every request (prefix-cache reuse, DESIGN.md §8)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable content-hash prefix reuse (comparison)")
    ap.add_argument("--engines", type=int, default=1,
                    help="serving-engine replicas over one shared host "
                         "tier (cluster tier + router, DESIGN.md §10)")
    ap.add_argument("--capacity-frames", type=int, default=None,
                    help="bound host DRAM to this many frames and open "
                         "the disk spill tier underneath (DESIGN.md §11; "
                         "cluster mode only)")
    ap.add_argument("--no-spill", action="store_true",
                    help="with --capacity-frames: hard-cap baseline — "
                         "evict over-cap prefix frames instead of "
                         "spilling them to disk")
    ap.add_argument("--translation", choices=("off", "flat", "radix"),
                    default="off",
                    help="meter KV page translations through the "
                         "coalesced-TLB + radix-walker model "
                         "(DESIGN.md §15); prints a per-app "
                         "translation-cycle summary line")
    args = ap.parse_args()
    if args.capacity_frames is not None and args.engines < 2:
        ap.error("--capacity-frames needs --engines >= 2 (the bounded "
                 "host tier is a cluster feature)")

    results = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs, steps = run(kind, args.requests, args.seed,
                               args.oversubscribe, args.fault_mode,
                               shared_prefix=args.shared_prefix,
                               prefix_cache=not args.no_prefix_cache,
                               n_engines=args.engines,
                               capacity_frames=args.capacity_frames,
                               spill=not args.no_spill,
                               translation=args.translation)
        if args.engines > 1:
            cluster_stats = eng.stats()
            s = cluster_stats.totals
            st = {}
            for e in eng.engines:
                for k, v in e.cache.stats().items():
                    st[k] = st.get(k, 0.0) + v / len(eng.engines)
        else:
            cluster_stats = None
            st = eng.cache.stats()
            s = eng.stats
        line = (f"[{kind:8}] {steps} engine steps | "
                f"{s.tok_per_s():7.1f} tok/s (CPU) | "
                f"coalesced {s.coalesced_mean:5.1%} | "
                f"CAC copies {s.compaction_copies} | "
                f"bloat {st.get('memory_bloat', 1):.2f}")
        if args.oversubscribe > 1.0:
            line += (f" | swaps {s.swaps_out}/{s.swaps_in} | "
                     f"faults {s.faults} in {s.fault_dmas} DMAs | "
                     f"{s.bytes_in / 1024:.0f} KiB in | "
                     f"{s.transfer_us:.0f} us bus "
                     f"({s.fault_hidden_us:.0f} hidden / "
                     f"{s.fault_exposed_us:.0f} exposed)")
        if args.shared_prefix:
            line += (f" | prefix {s.prefix_hits}/{s.prefix_misses} "
                     f"hit/miss | {s.prefix_reused_tokens} tok reused | "
                     f"admit {s.admit_hit_mean_us() / 1e3:.0f} ms hit vs "
                     f"{s.admit_cold_mean_us() / 1e3:.0f} ms cold | "
                     f"out {s.bytes_out / 1024:.0f} KiB")
        print(line)
        if cluster_stats is not None:
            for sub in cluster_stats.summary().splitlines():
                print(f"           {sub}")
        else:
            print(f"           {s.summary()}")
        if args.translation != "off":
            engines = eng.engines if args.engines > 1 else [eng]
            for e in engines:
                print(f"           engine[{e.engine_id}] "
                      f"{e.translation_meter.summary()}")
        results[kind] = {r.rid: tuple(r.out) for r in reqs}

    same = results["mosaic"] == results["gpu-mmu"]
    print(f"\napplication-transparency: outputs identical across managers "
          f"= {same}")
    assert same


if __name__ == "__main__":
    main()
