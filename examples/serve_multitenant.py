"""Multi-tenant serving on the Mosaic pool — the paper's setting as an
LLM-serving system.

Three tenants submit batched requests to one engine sharing one physical
KV pool.  The run reports, per manager (mosaic vs gpu-mmu baseline):
tokens/s, coalesced fraction (TLB-reach analogue), CAC compaction traffic,
and verifies the outputs are bit-identical — the manager is
application-transparent, the paper's headline property.

    PYTHONPATH=src python examples/serve_multitenant.py --requests 10
"""

import argparse

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import PoolGeometry
from repro.serving.engine import Request, ServingEngine


def run(manager_kind: str, n_requests: int, seed: int):
    cfg = get_smoke_config("qwen2.5-3b")
    geo = PoolGeometry(page_tokens=8, frame_pages=4, compact_threshold=0.4)
    eng = ServingEngine(cfg, geometry=geo, max_batch=4, max_seq=128,
                        manager_kind=manager_kind, seed=seed)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        T = int(rng.integers(16, 72))
        reqs.append(Request(
            rid=i, tenant=i % 3,
            prompt=rng.integers(0, cfg.vocab_size, T).astype(np.int32),
            max_new=int(rng.integers(4, 12))))
    for r in reqs:
        eng.submit(r)
    steps = eng.run_until_drained()
    return eng, reqs, steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    results = {}
    for kind in ("mosaic", "gpu-mmu"):
        eng, reqs, steps = run(kind, args.requests, args.seed)
        st = eng.cache.stats()
        print(f"[{kind:8}] {steps} engine steps | "
              f"{eng.stats.tok_per_s():7.1f} tok/s (CPU) | "
              f"coalesced {eng.stats.coalesced_mean:5.1%} | "
              f"CAC copies {eng.stats.compaction_copies} | "
              f"bloat {st.get('memory_bloat', 1):.2f}")
        results[kind] = {r.rid: tuple(r.out) for r in reqs}

    same = results["mosaic"] == results["gpu-mmu"]
    print(f"\napplication-transparency: outputs identical across managers "
          f"= {same}")
    assert same


if __name__ == "__main__":
    main()
