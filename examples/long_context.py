"""Long-context decode with host-DRAM demand paging (paper §1's trade-off).

A single sequence's KV cache exceeds the device pool, so cold pages live
in host DRAM and fault in at *base-page* granularity while translation
(the packed tables the kernel consumes) still works at *frame*
granularity — Mosaic's whole point, demonstrated end to end:

  * prefill a long prompt -> en-masse allocation, frames coalesce;
  * decode with a page-granular residency tracker: each step's working
    set faults in per page (small transfers), never per frame;
  * the same run with frame-granular faulting over-fetches ~16x.

    PYTHONPATH=src python examples/long_context.py --ctx 4096
"""

import argparse

import numpy as np

from repro.core.demand_paging import LinkModel, ResidencyTracker
from repro.core.manager import MosaicManager
from repro.core.pagepool import PoolConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ctx", type=int, default=4096)
    ap.add_argument("--decode-steps", type=int, default=64)
    ap.add_argument("--page-tokens", type=int, default=64)
    ap.add_argument("--frame-pages", type=int, default=16)
    args = ap.parse_args()

    ptok, fp = args.page_tokens, args.frame_pages
    pages = (args.ctx + ptok - 1) // ptok
    pool_pages = ((pages * 2 + fp - 1) // fp) * fp
    mgr = MosaicManager(PoolConfig(num_pages=pool_pages, frame_pages=fp,
                                   page_tokens=ptok))
    kv_page_bytes = ptok * 8 * 128 * 2 * 2      # kv=8 heads, dh=128, bf16, k+v
    link = LinkModel()

    # Prefill: en-masse allocation; frames coalesce with zero copies.
    mgr.allocate_tokens(0, args.ctx)
    t = mgr.table(0)
    print(f"prefill {args.ctx} tokens -> {t.num_pages} pages, "
          f"{sum(t.coalesced)}/{t.num_vframes} vframes coalesced, "
          f"copies={mgr.pool.stats['compaction_copies']}")

    # Decode with page-granular vs frame-granular demand paging.
    rng = np.random.default_rng(0)
    for granularity, span in (("page", 1), ("frame", fp)):
        tracker = ResidencyTracker(pool_pages, kv_page_bytes, link)
        total_us = 0.0
        for step in range(args.decode_steps):
            # Attention sparsely revisits history (sliding window + a few
            # random lookback pages) — the regime where paging wins.
            recent = list(range(max(0, t.num_pages - 4), t.num_pages))
            lookback = rng.integers(0, t.num_pages, size=4).tolist()
            need_vpns = sorted(set(recent + lookback))
            ppns = []
            for v in need_vpns:
                base = (t.ppn[v] // span) * span
                ppns.extend(range(base, base + span))
            batch = tracker.fault_in(ppns)
            total_us += batch.transfer_us
        s = tracker.stats
        print(f"[{granularity:5}-granular faults] faults={s['faults']:4d} "
              f"bytes_in={s['bytes_in'] / 1e6:7.2f} MB "
              f"transfer={total_us / 1e3:6.2f} ms")

    print("\npage-granular transfer moves only what the step touches; "
          "frame-granular over-fetches the rest of each frame — Mosaic "
          "gives frame-level translation WITH page-level transfer.")


if __name__ == "__main__":
    main()
